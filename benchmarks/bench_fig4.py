"""Fig 4: Token-to-Expert accuracy vs overhead vs end-to-end performance.

Trains the REAL predictor ladder (probability -> conditional -> FFN ->
LSTM) on synthetic Mixtral-geometry corpora at skew 1.4 and 2.0, measures
top-1 accuracy on a held-out split and analytic overhead FLOPs, then feeds
(accuracy, overhead) into the simulator to get normalized end-to-end
performance. Reproduces the U-shape and the skew effect ("higher skewness
makes prediction easier/cheaper").
"""

from __future__ import annotations

import os


from repro.configs.registry import get_config
from repro.core.gps import T2EPoint, run_gps
from repro.core.predictors import (ConditionalProbabilityModel, FFNPredictor,
                                   LSTMPredictor, ProbabilityModel, accuracy)
from repro.core.simulator import A100_NVLINK, attention_flops, \
    ffn_flops_per_token
from repro.data.synthetic import make_routing_trace

E, L, V, S = 8, 4, 2048, 128
MIX = get_config("mixtral-8x7b")


def model_flops_per_token() -> float:
    """Mixtral per-token forward FLOPs (the overhead denominator)."""
    att = attention_flops(MIX, 1, 512) * MIX.num_layers
    ffn = ffn_flops_per_token(MIX) * MIX.num_layers
    return att + ffn + 2 * MIX.d_model * MIX.vocab_size


def ladder_for(skew: float, seed: int = 0, verbose=True):
    smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
    n_seq, seq_len = (24, 32) if smoke else (96, S)
    ffn_steps, lstm_steps = (10, 5) if smoke else (150, 120)
    tr = make_routing_trace(num_sequences=n_seq, seq_len=seq_len, vocab=V,
                            num_experts=E, num_layers=L, skew=skew,
                            predictability=0.85, seed=seed)
    n = int(tr.tokens.shape[0] * 0.8)
    tok_tr, ex_tr = tr.tokens[:n], tr.experts[:, :n]
    tok_te, ex_te = tr.tokens[n:], tr.experts[:, n:]
    denom = model_flops_per_token()

    ladder = [
        ("probability", ProbabilityModel(L, E).fit(ex_tr)),
        ("conditional", ConditionalProbabilityModel(L, E, V).fit(ex_tr, tok_tr)),
        ("ffn", FFNPredictor(L, E, V, seed=seed).fit(
            ex_tr, tok_tr, steps=ffn_steps, batch=32)),
        ("lstm", LSTMPredictor(L, E, V, seed=seed).fit(
            ex_tr, tok_tr, steps=lstm_steps, batch=16)),
    ]
    # The paper MEASURES overhead on A100 at batch 1 (Sec 5 admits tiny
    # predictors are launch/latency-bound there, not FLOPs-bound) and fits
    # an exponential overhead(accuracy). We keep that measured calibration
    # (default_t2e_curve's fit) applied at OUR measured accuracies, and
    # also report the pure-FLOPs overhead — at production batch sizes the
    # analytic number is the right one (recorded in EXPERIMENTS.md as a
    # beyond-paper observation: T2E overhead amortises with batch).
    from repro.core.gps import default_t2e_curve, fit_overhead_curve
    paper_fit = fit_overhead_curve(default_t2e_curve(skew))
    points = []
    for name, m in ladder:
        acc = accuracy(m.predict(tok_te), ex_te)
        over_flops = m.flops_per_token(MIX.num_layers) / denom
        over = max(paper_fit(acc), 1e-3)
        points.append(T2EPoint(name, acc, over))
        if verbose:
            print(f"  skew={skew:.1f} {name:12s} acc={acc:.3f} "
                  f"overhead={over:.4f} (analytic flops-only: "
                  f"{over_flops:.2e})")
    return points


def run(verbose: bool = True):
    rows = []
    for skew in (1.4, 2.0):
        if verbose:
            print(f"predictor ladder @ skew {skew}:")
        points = ladder_for(skew, verbose=verbose)
        rep = run_gps(MIX, A100_NVLINK, skew=skew, t2e_curve=points)
        base = rep.baseline.total
        for r in rep.t2e_points:
            rows.append(dict(skew=skew, predictor=r.predictor,
                             accuracy=round(r.accuracy, 3),
                             norm_perf=round(base / r.total, 3)))
        if verbose:
            best = rep.best_t2e
            print(f"  best T2E point: {best.predictor} "
                  f"(acc={best.accuracy:.2f}) norm_perf="
                  f"{base / best.total:.3f}; dist_only="
                  f"{base / rep.dist_only.total:.3f}")
    # derived: accuracy of the best predictor at high skew minus low skew
    # (>0: higher skew shifts the sweet spot toward higher accuracy)
    by_skew = {}
    for r in rows:
        cur = by_skew.get(r["skew"])
        if cur is None or r["norm_perf"] > cur["norm_perf"]:
            by_skew[r["skew"]] = r
    derived = by_skew[2.0]["accuracy"] - by_skew[1.4]["accuracy"]
    return rows, derived


if __name__ == "__main__":
    run()
