"""Fig 6: single-layer Mixtral prefill latency breakdown by strategy,
skewness and interconnect (NVLink 600 GB/s vs PCIe) — including the
paper's >23% headline at skew 1.4 / NVLink. Also sweeps the TPU v5e
production target (ICI vs DCN) — the hardware-adaptation columns.
"""

from __future__ import annotations

from repro.configs.registry import get_config
from repro.core.gps import run_gps
from repro.core.simulator import (A100_NVLINK, A100_PCIE, TPU_V5E_DCN,
                                  TPU_V5E_POD)

MIX = get_config("mixtral-8x7b")
SKEWS = (1.0, 1.4, 2.0, 3.0)
HARDWARE = (A100_NVLINK, A100_PCIE, TPU_V5E_POD, TPU_V5E_DCN)


def run(verbose: bool = True):
    rows = []
    headline = None
    for hw in HARDWARE:
        if verbose:
            print(f"\n{hw.name} (link {hw.link_bw / 1e9:.0f} GB/s)")
            print(f"{'skew':>5s} {'strategy':>16s} {'attn':>8s} {'ar':>8s} "
                  f"{'disp':>8s} {'ffn':>8s} {'comb':>8s} {'over':>8s} "
                  f"{'total':>8s}")
        for skew in SKEWS:
            rep = run_gps(MIX, hw, batch=1, seq=512, skew=skew)
            for res in (rep.baseline, rep.dist_only, rep.best_t2e):
                lb = res.latency
                rows.append(dict(hw=hw.name, skew=skew,
                                 strategy=res.strategy,
                                 accuracy=round(res.accuracy, 3),
                                 total_ms=round(lb.total * 1e3, 4),
                                 **{k: round(v * 1e3, 4)
                                    for k, v in lb.as_dict().items()
                                    if k != "total"}))
                if verbose:
                    print(f"{skew:5.1f} {res.strategy:>16s} "
                          f"{lb.attention*1e3:8.3f} {lb.allreduce*1e3:8.3f} "
                          f"{lb.dispatch*1e3:8.3f} {lb.ffn*1e3:8.3f} "
                          f"{lb.combine*1e3:8.3f} {lb.overhead*1e3:8.3f} "
                          f"{lb.total*1e3:8.3f}")
            if hw is A100_NVLINK and abs(skew - 1.4) < 1e-6:
                headline = rep.dist_only_speedup_over_t2e
    if verbose and headline is not None:
        print(f"\nHEADLINE (Mixtral, skew 1.4, NVLink): Distribution-Only is "
              f"{headline:+.1%} faster than the best Token-to-Expert point "
              f"(paper claims >23%)")
    return rows, headline


if __name__ == "__main__":
    run()
