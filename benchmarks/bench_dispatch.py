"""Dispatch hot-path benchmark: sort-based vs one-hot send-buffer packing.

Times every dispatch phase (route / pack / a2a / ffn / combine, see
`repro.moe.profile`) for both ``dispatch_impl`` formulations on the same
shapes, verifies on the way that the two packers produce bit-identical
send buffers / stats / drop decisions, and writes the machine-readable
``BENCH_dispatch.json`` consumed by the CI bench-regression gate.

The key derived quantity is ``pack_speedup`` — how much faster the
argsort+gather packer builds the send buffer than the one-hot scatter
oracle. Route/a2a/ffn/combine are impl-independent and reported for
context (they are the costs MoE-GPS weighs a predictor against).
"""

from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _check_pack_equivalence(T: int, K: int, S: int, cap: int, seed: int = 0):
    """The benchmark doubles as a spot-check: both packers must agree
    exactly (send buffer, mask, destinations, counts, drops)."""
    from repro.moe.dispatch import _pack_onehot, _pack_sort
    rng = np.random.default_rng(seed)
    N = T * K
    x = jnp.asarray(rng.normal(size=(T, 16)), jnp.float32)
    token_of = jnp.arange(N, dtype=jnp.int32) // K
    gslot = jnp.asarray(rng.integers(0, S, N), jnp.int32)
    valid = jnp.asarray(rng.random(N) < 0.9)
    a = _pack_onehot(x, token_of, gslot, valid, num_classes=S, cap=cap)
    b = _pack_sort(x, token_of, gslot, valid, num_classes=S, cap=cap)
    names = ("send", "in_cap", "dest", "counts", "dropped")
    for av, bv, name in zip(a, b, names):
        assert np.array_equal(np.asarray(av), np.asarray(bv)), (
            f"sort/onehot packers disagree on {name}")


def run(verbose: bool = True, smoke: bool = None):
    from repro.moe.profile import (PHASES, dispatch_phase_times,
                                   pack_impl_times)

    if smoke is None:
        smoke = _smoke()
    if smoke:
        shape = dict(tokens=4096, num_experts=64, top_k=2, d_model=256,
                     d_ff=128, ranks=4, capacity_factor=1.25)
        iters = 10
    else:
        shape = dict(tokens=8192, num_experts=128, top_k=2, d_model=256,
                     d_ff=256, ranks=8, capacity_factor=1.25)
        iters = 12

    _check_pack_equivalence(T=512, K=2, S=shape["num_experts"], cap=24)

    # full per-phase context on the default (sort) pipeline, then the
    # impl-dependent phase head-to-head with interleaved measurement so
    # machine drift can't skew the ratio
    phases = dispatch_phase_times(impl="sort", iters=iters, **shape)
    pack_shape = {k: shape[k] for k in ("tokens", "num_experts", "top_k",
                                        "d_model", "capacity_factor")}
    pack = pack_impl_times(iters=iters, **pack_shape)
    shared = {k: phases[k] for k in PHASES if k != "pack"}
    totals = {impl: sum(shared.values()) + pack[impl] for impl in pack}
    speedup = pack["onehot"] / max(pack["sort"], 1e-12)
    e2e = totals["onehot"] / max(totals["sort"], 1e-12)

    doc = {
        "schema": 1,
        "smoke": smoke,
        "config": shape,
        "shared_phases_us": {k: v * 1e6 for k, v in shared.items()},
        "pack_us": {impl: v * 1e6 for impl, v in pack.items()},
        "total_us": {impl: v * 1e6 for impl, v in totals.items()},
        "pack_speedup": speedup,
        "total_speedup": e2e,
    }
    out_dir = os.environ.get("REPRO_BENCH_OUT", ".")
    path = os.path.join(out_dir, "BENCH_dispatch.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)

    if verbose:
        print(f"shapes: {shape}")
        print(f"{'phase':8s} {'sort':>10s} {'onehot':>10s}")
        for k in PHASES:
            s = pack["sort"] if k == "pack" else shared[k]
            o = pack["onehot"] if k == "pack" else shared[k]
            print(f"{k:8s} {s*1e6:9.0f}us {o*1e6:9.0f}us")
        print(f"{'total':8s} {totals['sort']*1e6:9.0f}us "
              f"{totals['onehot']*1e6:9.0f}us")
        print(f"pack speedup (onehot/sort): {speedup:.2f}x | "
              f"end-to-end {e2e:.2f}x | wrote {path}")

    # policy lives in benchmarks/check_regression.py (pack_speedup >= 1.0
    # gates CI); here just flag a below-par measurement for the log
    if verbose and speedup < 1.3:
        print(f"NOTE: pack speedup {speedup:.2f}x below the 1.3x target "
              "(noisy runner?) — the CI gate fails only below 1.0x")

    summary = {"pack_speedup": speedup, "total_speedup": e2e,
               "sort_pack_us": pack["sort"] * 1e6,
               "onehot_pack_us": pack["onehot"] * 1e6}
    for k, v in shared.items():
        summary[f"{k}_us"] = v * 1e6
    derived = (f"pack_speedup={speedup:.2f}x total_speedup={e2e:.2f}x "
               f"sort_pack={pack['sort']*1e6:.0f}us "
               f"onehot_pack={pack['onehot']*1e6:.0f}us")
    return summary, derived


if __name__ == "__main__":
    run(verbose=True)
