"""Appendix C: generality across MoE architectures (LLaMA-MoE + Switch
Transformer, same datasets/hardware as the Mixtral experiments).

The paper's claim: the strategy tradeoffs transfer across expert
construction and routing choices. We run the same GPS sweep for all three
models and check the GUIDELINE DECISIONS agree: Distribution-Only at
low skew / fast links, Token-to-Expert gaining as both degrade.
"""

from __future__ import annotations

from repro.configs.registry import get_config
from repro.core.gps import run_gps
from repro.core.simulator import A100_NVLINK, A100_PCIE

MODELS = ("mixtral-8x7b", "llama-moe-3.5b", "switch-base-128")
SKEWS = (1.4, 2.0, 3.0)


def run(verbose: bool = True):
    rows = []
    decisions = {}
    for name in MODELS:
        cfg = get_config(name)
        if verbose:
            print(f"\n{name} (E={cfg.moe.num_experts} top-{cfg.moe.top_k}, "
                  f"{cfg.activation} FFN, KV={cfg.num_kv_heads})")
            print(f"{'hw':>14s} " + " ".join(f"skew{s:<5.1f}" for s in SKEWS))
        for hw in (A100_NVLINK, A100_PCIE):
            row = []
            for skew in SKEWS:
                rep = run_gps(cfg, hw, batch=1, seq=512, skew=skew)
                win = "DIST" if rep.best is rep.dist_only else "T2E"
                row.append(win)
                rows.append(dict(model=name, hw=hw.name, skew=skew,
                                 winner=win,
                                 saving_diff=round(rep.saving_difference, 4)))
                decisions[(name, hw.name, skew)] = win
            if verbose:
                print(f"{hw.name:>14s} " + " ".join(f"{w:>9s}" for w in row))
    # derived: the paper claims the TREND is consistent, not the exact
    # decision points (smaller experts shift the T2E frontier left).
    # Check per model: once T2E wins it keeps winning as skew grows, and
    # the PCIe row flips at a skew <= the NVLink row's.
    monotone = 0
    for m in MODELS:
        ok = True
        for h in (A100_NVLINK.name, A100_PCIE.name):
            seq = [decisions[(m, h, s)] for s in SKEWS]
            if "DIST" in seq[seq.index("T2E"):] if "T2E" in seq else False:
                ok = False
        def flip(h):
            seq = [decisions[(m, h, s)] for s in SKEWS]
            return seq.index("T2E") if "T2E" in seq else len(SKEWS)
        if flip(A100_PCIE.name) > flip(A100_NVLINK.name):
            ok = False
        monotone += ok
    if verbose:
        print(f"\ntrend consistency (T2E frontier monotone in skew and "
              f"bandwidth): {monotone}/{len(MODELS)} models "
              f"(paper Appendix C: consistent system-level behaviour; "
              f"exact flip points shift with expert size)")
    return rows, monotone / len(MODELS)


if __name__ == "__main__":
    run()
