"""Reproduce the paper's Figure-1 guideline chart: for every (hardware,
skewness) deployment point, which prediction strategy minimises latency?

  PYTHONPATH=src python examples/gps_guidelines.py [--arch mixtral-8x7b]

Also runs the two assigned MoE architectures (arctic-480b,
deepseek-v2-lite-16b) through MoE-GPS on the TPU v5e production target.
"""

import argparse


from repro.configs.registry import get_config
from repro.core.gps import run_gps
from repro.core.simulator import (A100_NVLINK, A100_PCIE, TPU_V5E_16,
                                  TPU_V5E_DCN, TPU_V5E_POD)

SKEWS = (1.2, 1.4, 1.7, 2.0, 2.5, 3.0)


def chart(cfg, hardwares, batch, seq):
    print(f"\n=== {cfg.name} (E={cfg.moe.num_experts} "
          f"top-{cfg.moe.top_k}) batch={batch} seq={seq} ===")
    print(f"{'hardware':>18s} | " +
          " ".join(f"{s:>7.1f}" for s in SKEWS) + "   (skewness ->)")
    for hw in hardwares:
        row = []
        for skew in SKEWS:
            rep = run_gps(cfg, hw, batch=batch, seq=seq, skew=skew)
            best = rep.best
            row.append("DIST" if best is rep.dist_only
                       else f"T2E.{best.accuracy:.1f}")
        print(f"{hw.name:>18s} | " + " ".join(f"{r:>7s}" for r in row))
    print("DIST = Distribution-Only; T2E.x = Token-to-Expert at accuracy x")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    args = ap.parse_args()

    # the paper's validation point: 4xA100, batch 1, seq 512
    chart(get_config(args.arch), (A100_NVLINK, A100_PCIE), 1, 512)

    # the production target: TPU v5e, serving-scale batches
    for arch in ("arctic-480b", "deepseek-v2-lite-16b"):
        chart(get_config(arch), (TPU_V5E_16, TPU_V5E_POD, TPU_V5E_DCN),
              32, 2048)

    print("\nguideline sentences (paper Fig 1):")
    for hw, skew in ((A100_NVLINK, 1.4), (A100_PCIE, 3.0),
                     (TPU_V5E_DCN, 2.0)):
        rep = run_gps(get_config(args.arch), hw, skew=skew)
        print(f"  [{hw.name}, skew {skew}] {rep.guideline()}")


if __name__ == "__main__":
    main()
