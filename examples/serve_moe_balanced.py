"""End-to-end serving driver (the paper's feature, measured for real).

Serves batched requests through a Mixtral-geometry MoE on an 8-device
(2 data x 4 model) mesh, once per strategy, and reports MEASURED per-rank
token loads and wall-clock throughput:

  PYTHONPATH=src python examples/serve_moe_balanced.py

no prediction      -> bottleneck rank carries ~skew x the mean load
Distribution-Only  -> Algorithm 1 duplication rebalances to ~(1+eps)
Token-to-Expert    -> tokens pre-routed from a trained predictor
                      (+ correction round for mispredictions)

Re-execs itself with 8 fake XLA devices so the production shard_map
dispatch path (all_to_all, replica pools) actually runs.
"""

import os
import sys

if os.environ.get("XLA_FLAGS", "").find("device_count") < 0:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.predictors import ConditionalProbabilityModel, accuracy
from repro.data.synthetic import make_routing_trace
from repro.models.transformer import init_model
from repro.serve import BatchScheduler, Request, ServeConfig, ServeEngine

BATCH, SEQ, NEW, REQUESTS = 8, 64, 4, 24


def main():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("mixtral-8x7b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    print(f"{cfg.name}: E={cfg.moe.num_experts} top-{cfg.moe.top_k} on "
          f"mesh {dict(mesh.shape)} (EP over 'model')\n")

    # a predictable routing corpus + a trained Token-to-Expert predictor
    trace = make_routing_trace(num_sequences=96, seq_len=SEQ,
                               vocab=cfg.vocab_size,
                               num_experts=cfg.moe.num_experts,
                               num_layers=cfg.num_layers, skew=1.8,
                               predictability=0.9, seed=0)
    predictor = ConditionalProbabilityModel(
        cfg.num_layers, cfg.moe.num_experts, cfg.vocab_size
    ).fit(trace.experts[:, :64], trace.tokens[:64])
    acc = accuracy(predictor.predict(trace.tokens[64:]), trace.experts[:, 64:])
    print(f"Token-to-Expert predictor (conditional-frequency): "
          f"held-out accuracy {acc:.2f}\n")

    results = {}
    for strategy in ("none", "dist_only", "token_to_expert"):
        eng = ServeEngine(
            cfg, params,
            ServeConfig(strategy=strategy, dup_slots=1,
                        max_len=SEQ + NEW),
            mesh=mesh, ep_ranks=4,
            predictor=predictor if strategy == "token_to_expert" else None)

        sched = BatchScheduler(BATCH, SEQ)
        for rid in range(REQUESTS):
            sched.submit(Request(rid, trace.tokens[rid % 96],
                                 max_new_tokens=NEW))
        t0 = time.time()
        last_stats = None
        while sched.has_work():
            b = sched.next_batch()
            logits, cache, stats = eng.prefill(
                {"tokens": jnp.asarray(b["tokens"])})
            tok = logits[:, -1].argmax(-1).astype(jnp.int32)[:, None]
            outs = [tok]
            for t in range(NEW - 1):
                tok, _, cache, _ = eng.decode(tok, cache, SEQ + t)
                outs.append(tok)
            sched.finish(b["requests"],
                         np.asarray(jnp.concatenate(outs, 1)))
            last_stats = stats
        dt = time.time() - t0

        rl = eng.rank_loads(np.asarray(last_stats["slot_counts"]))
        bneck = float((rl.max(1) / rl.mean(1)).mean())
        results[strategy] = (bneck, dt)
        print(f"{strategy:16s}: served {len(sched.completed)} reqs in "
              f"{dt:5.1f}s | measured rank loads (layer 0) = "
              f"{rl[0].astype(int).tolist()} | bottleneck/mean = {bneck:.2f}")

    print("\nsummary (bottleneck/mean; 1.00 = perfectly balanced):")
    for s, (b, dt) in results.items():
        print(f"  {s:16s} {b:.2f}")
    assert results["dist_only"][0] < results["none"][0], \
        "duplication must improve measured balance"
    print("OK: prediction-guided duplication measurably rebalanced the "
          "expert load (paper's end-to-end claim).")

    continuous_demo(cfg, params, mesh, predictor)


def continuous_demo(cfg, params, mesh, predictor):
    """Continuous batching on the same mesh: requests arrive on a Poisson
    clock, mixed prefill+decode iterations, online GPS controller switching
    strategy as the measured skew moves."""
    from repro.serve import (ContinuousConfig, ContinuousEngine,
                             ControllerConfig, OnlineGPSController)
    from repro.workloads import skew_shift_trace, to_serve_requests

    print("\n--- continuous batching (paged KV + online GPS) ---")
    full_cfg = get_config("mixtral-8x7b")
    controller = OnlineGPSController(
        full_cfg,
        ControllerConfig(window_iters=8, patience=1,
                         skew_cap_observed=cfg.moe.num_experts
                         / cfg.moe.top_k,
                         skew_cap_target=full_cfg.moe.num_experts
                         / full_cfg.moe.top_k),
        predictor_available=True, initial_strategy="dist_only")
    eng = ContinuousEngine(
        cfg, params,
        ContinuousConfig(max_slots=8, prefill_len=64, block_size=16,
                         max_len=96, metrics_window=8),
        mesh=mesh, ep_ranks=4, predictor=predictor, controller=controller)
    eng.warmup()
    trace = skew_shift_trace(cfg.vocab_size, horizon=30.0, rate=1.5, seed=1)
    end = eng.run_trace(to_serve_requests(trace), time_scale=10.0)
    eng.assert_no_recompiles()
    s = eng.metrics.summary()
    print(f"served {int(s['completed'])}/{len(trace)} requests by "
          f"{end:.1f}s | TTFT p99 {s['ttft_p99']*1e3:.0f}ms | "
          f"TPOT p99 {s['tpot_p99']*1e3:.0f}ms | "
          f"{s['throughput_tok_s']:.0f} tok/s")
    for line in controller.switch_log():
        print("  switch:", line)
    assert int(s["completed"]) == len(trace)
    print("OK: continuous engine served the trace with zero recompiles.")


if __name__ == "__main__":
    main()
