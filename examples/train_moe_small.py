"""Train a ~100M-parameter MoE decoder for a few hundred steps (CPU-sized
end-to-end training driver; the assignment's (b) training example).

  PYTHONPATH=src python examples/train_moe_small.py [--steps 200]

Uses the full training substrate: WSD/cosine schedule, AdamW with global-
norm clipping, router aux/z losses, expert-count telemetry (the routing
skew the paper's predictors consume), and checkpointing.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig, ModelConfig
from repro.data.synthetic import token_batches
from repro.models.transformer import Runtime, init_model
from repro.optim.adamw import adamw_init
from repro.optim.schedules import cosine_schedule
from repro.train import checkpoint as ckpt
from repro.train.steps import make_train_step

# ~100M params: 8 layers, d=512, 8 experts (top-2) of d_ff=1024, 32k vocab
SMALL_MOE = ModelConfig(
    name="moe-100m", family="moe", num_layers=8, d_model=512, num_heads=8,
    num_kv_heads=4, d_ff=1536, vocab_size=32768, head_dim=64,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=1024,
                  capacity_factor=1.5),
    source="this repo (assignment example)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/moe_100m.npz")
    args = ap.parse_args()

    cfg = SMALL_MOE
    params = init_model(jax.random.PRNGKey(0), cfg)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params "
          f"({cfg.moe.num_experts} experts top-{cfg.moe.top_k})")

    opt = adamw_init(params)
    lr_fn = cosine_schedule(3e-4, warmup=20, total=args.steps)
    step = jax.jit(make_train_step(cfg, Runtime(), lr_fn=lr_fn))
    gen = token_batches(0, cfg.vocab_size, args.batch, args.seq)

    losses, t0 = [], time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
        if i % 20 == 0 or i == args.steps - 1:
            c = np.asarray(m["expert_counts"]).sum(0)
            print(f"step {i:4d} loss={losses[-1]:.4f} "
                  f"aux={float(m['aux_loss']):.4f} "
                  f"routing_skew={c.max()/c.mean():.2f} "
                  f"lr={float(m['lr']):.2e}")
    dt = time.time() - t0
    print(f"\n{args.steps} steps in {dt:.0f}s ({dt/args.steps*1e3:.0f} "
          f"ms/step); loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0] - 0.5, "training must make progress"
    ckpt.save(args.ckpt, {"params": params, "opt": opt})
    print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
