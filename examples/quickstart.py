"""Quickstart: the whole stack in one minute on one CPU.

  PYTHONPATH=src python examples/quickstart.py

1. pick an architecture config (--arch style registry),
2. train the reduced variant a few steps,
3. serve a batch with Distribution-Only expert duplication,
4. ask MoE-GPS which prediction strategy this deployment should use.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.gps import run_gps
from repro.core.simulator import A100_NVLINK, TPU_V5E_POD
from repro.data.synthetic import token_batches
from repro.models.transformer import Runtime, init_model
from repro.optim.adamw import adamw_init
from repro.serve import ServeConfig, ServeEngine
from repro.train.steps import make_train_step


def main():
    cfg = get_config("mixtral-8x7b").reduced()
    print(f"arch={cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"E={cfg.moe.num_experts} top-{cfg.moe.top_k}")

    # --- 2. train a few steps -------------------------------------------
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, Runtime(), lr_fn=lambda s: 1e-3))
    gen = token_batches(0, cfg.vocab_size, batch=4, seq_len=32)
    for i in range(5):
        batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
        params, opt, metrics = step(params, opt, batch)
        print(f"train step {i}: loss={float(metrics['loss']):.3f}")

    # --- 3. serve with Distribution-Only duplication --------------------
    eng = ServeEngine(cfg, params, ServeConfig(strategy="dist_only",
                                               max_len=64))
    out, tele = eng.generate({"tokens": jnp.asarray(next(gen)["tokens"])},
                             max_new_tokens=8)
    print(f"served batch -> generated {out.shape}, measured routing "
          f"skew={tele.get('skew', 0):.2f}")
    print(f"estimated expert distribution (layer 0): "
          f"{np.round(eng.estimator.predict()[0], 3)}")

    # --- 4. which strategy should this deployment use? ------------------
    full = get_config("mixtral-8x7b")
    for hw in (A100_NVLINK, TPU_V5E_POD):
        rep = run_gps(full, hw, skew=tele.get("skew", 1.4))
        print(f"[{hw.name}] {rep.guideline()}")


if __name__ == "__main__":
    main()
